"""4-bit packing layout: roundtrip exactness + byte accounting +
property tests (hypothesis-driven when available, fixed seeds otherwise)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import seed_property

from repro.core import mx as mxlib
from repro.kernels import packing, ref


def test_pack_unpack_codes_roundtrip():
    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.integers(0, 15, (16, 64)), jnp.uint8)
    np.testing.assert_array_equal(
        np.asarray(packing.unpack_codes(packing.pack_codes(c))),
        np.asarray(c))


def test_pack_codes_odd_axis_raises():
    with pytest.raises(ValueError, match="even"):
        packing.pack_codes(jnp.zeros((4, 33), jnp.uint8))


def test_pack_weight_rejects_unpackable():
    w = jnp.zeros((64, 8), jnp.float32)
    with pytest.raises(ValueError, match="packable"):
        packing.pack_weight(w, fmt="mxfp8")
    with pytest.raises(ValueError, match="divisible"):
        packing.pack_weight(jnp.zeros((48, 8), jnp.float32))


def test_scale_e8m0_roundtrip():
    e = jnp.asarray([-20, -3, 0, 1, 7, 30], jnp.float32)
    s = jnp.exp2(e)
    b = packing.pack_scales_e8m0(s)
    np.testing.assert_allclose(np.asarray(packing.unpack_scales_e8m0(b)),
                               np.asarray(s))


@seed_property(max_examples=20)
def test_property_weight_bundle_exact(seed):
    """pack -> unpack == fake-quantized weight, and the byte count matches
    mx.packed_nbytes (the roofline accounting) — for every packable fmt."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    for fmt in packing.PACKABLE_FMTS:
        cfg = mxlib.MXConfig(fmt=fmt, block_size=32)
        bundle = packing.pack_weight(w, fmt)
        wq = packing.unpack_weight(bundle)
        expect = mxlib.quantize(w.T, cfg, ste=False).T
        np.testing.assert_array_equal(np.asarray(wq), np.asarray(expect))
        assert packing.packed_bundle_nbytes(bundle) == \
            mxlib.packed_nbytes(w.shape, cfg)


@seed_property(max_examples=20)
def test_property_pack_idempotent_on_grid(seed):
    """An already-quantized weight packs losslessly (bitwise) — the
    invariant the artifact store's zero-requantization load relies on."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((96, 16)), jnp.float32)
    for fmt in packing.PACKABLE_FMTS:
        cfg = mxlib.MXConfig(fmt=fmt, block_size=32)
        wq = mxlib.quantize(w.T, cfg, ste=False).T
        back = packing.unpack_weight(packing.pack_weight(wq, fmt))
        np.testing.assert_array_equal(np.asarray(back), np.asarray(wq))


def test_pack_weight_leading_dims():
    """Layer-stacked (L, K, N) and expert-batched (L, E, K, N) weights
    pack along the contraction axis; per-slice results match 2-D packs."""
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.standard_normal((2, 3, 64, 16)), jnp.float32)
    bundle = packing.pack_weight(w, "mxfp4")
    assert bundle["codes_packed"].shape == (2, 3, 32, 16)
    assert bundle["scales_e8m0"].shape == (2, 3, 2, 16)
    full = packing.unpack_weight(bundle)
    for l in range(2):
        for e in range(3):
            single = packing.unpack_weight(packing.pack_weight(w[l, e]))
            np.testing.assert_array_equal(np.asarray(full[l, e]),
                                          np.asarray(single))


def test_packed_weight_pytree():
    """PackedWeight slices under tree.map (the scan path) and dequantizes
    inside jit to the same values as the dense equivalent."""
    rng = np.random.default_rng(4)
    w = jnp.asarray(rng.standard_normal((3, 64, 16)), jnp.float32)
    cfg = mxlib.MXConfig(fmt="mxfp4", block_size=32)
    wq = jnp.swapaxes(mxlib.quantize(jnp.swapaxes(w, -1, -2), cfg,
                                     ste=False), -1, -2)
    pw = packing.PackedWeight.from_dense(wq)
    assert pw.shape == (3, 64, 16) and pw.nbytes_packed == \
        mxlib.packed_nbytes(wq.shape, cfg)
    sl = jax.tree.map(lambda a: a[1], pw)
    assert isinstance(sl, packing.PackedWeight) and sl.shape == (64, 16)
    np.testing.assert_array_equal(np.asarray(sl.to_dense()),
                                  np.asarray(wq[1]))
    dense = jax.jit(packing.maybe_dense)(pw)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(wq))


def test_bundle_feeds_kernel():
    """Unpacked bundle codes/scales drive the mx_matmul oracle."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((64, 32)) * 0.2, jnp.float32)
    bundle = packing.pack_weight(w)
    codes = packing.unpack_codes(
        jnp.swapaxes(bundle["codes_packed"], -1, -2)).T   # (K, N)
    scales = packing.unpack_scales_e8m0(bundle["scales_e8m0"])
    y = ref.mx_matmul_ref(x, codes, scales)
    cfg = mxlib.MXConfig(fmt="mxfp4")
    expect = mxlib.quantize(x, cfg, ste=False) @ \
        mxlib.quantize(w.T, cfg, ste=False).T
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect),
                               atol=1e-4, rtol=1e-5)
