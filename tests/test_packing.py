"""4-bit packing layout: roundtrip exactness + byte accounting +
hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import mx as mxlib
from repro.kernels import packing, ref


def test_pack_unpack_codes_roundtrip():
    rng = np.random.default_rng(0)
    c = jnp.asarray(rng.integers(0, 15, (16, 64)), jnp.uint8)
    np.testing.assert_array_equal(
        np.asarray(packing.unpack_codes(packing.pack_codes(c))),
        np.asarray(c))


def test_scale_e8m0_roundtrip():
    e = jnp.asarray([-20, -3, 0, 1, 7, 30], jnp.float32)
    s = jnp.exp2(e)
    b = packing.pack_scales_e8m0(s)
    np.testing.assert_allclose(np.asarray(packing.unpack_scales_e8m0(b)),
                               np.asarray(s))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_property_weight_bundle_exact(seed):
    """pack -> unpack == fake-quantized weight, and the byte count matches
    mx.packed_nbytes (the roofline accounting)."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    bundle = packing.pack_weight(w)
    wq = packing.unpack_weight(bundle)
    cfg = mxlib.MXConfig(fmt="mxfp4", block_size=32)
    expect = mxlib.quantize(w.T, cfg, ste=False).T
    np.testing.assert_allclose(np.asarray(wq), np.asarray(expect),
                               atol=1e-6)
    assert packing.packed_bundle_nbytes(bundle) == \
        mxlib.packed_nbytes(w.shape, cfg)


def test_bundle_feeds_kernel():
    """Unpacked bundle codes/scales drive the mx_matmul oracle."""
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((64, 32)) * 0.2, jnp.float32)
    bundle = packing.pack_weight(w)
    codes = packing.unpack_codes(bundle["codes_packed"].T).T
    scales = packing.unpack_scales_e8m0(bundle["scales_e8m0"])
    y = ref.mx_matmul_ref(x, codes, scales)
    cfg = mxlib.MXConfig(fmt="mxfp4")
    expect = mxlib.quantize(x, cfg, ste=False) @ \
        mxlib.quantize(w.T, cfg, ste=False).T
    np.testing.assert_allclose(np.asarray(y), np.asarray(expect),
                               atol=1e-4, rtol=1e-5)
