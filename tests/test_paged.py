"""Paged MX KV cache: the ``PagedKV`` pool layout, the block-table
flash-decode kernel vs its oracle, the ``BlockAllocator`` lifecycle
(alloc / free / ref-count / LRU eviction), and end-to-end paged serving —
bit-identical to the contiguous continuous scheduler for
``kv_cache='none'``, within the pinned tolerance otherwise, with
hash-based prefix caching (shared prompts prefilled exactly once),
copy-on-write of partial pages, and pool-exhaustion backpressure.
See ``docs/paged-kv.md``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.quantize import QuantMode
from repro.kernels import ops, packing
from repro.kernels.mx_attention import _pick_chunk
from repro.models import api
from repro.serving.engine import BlockAllocator, Engine, Request

KV_FMTS = ["mxfp8", "mxint8", "mxfp4", "mxint4"]


def _cfg(**kw):
    base = dict(name="tiny", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                attn_chunk=16)
    base.update(kw)
    return ArchConfig(**base)


def _moe_cfg(**kw):
    base = dict(name="tiny-moe", family="moe", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                n_experts=4, top_k=2, n_shared_experts=1, attn_chunk=16,
                capacity_factor=4.0)
    base.update(kw)
    return ArchConfig(**base)


def _requests(cfg, lens, news, seed=0, prefix=None):
    rng = np.random.default_rng(seed)
    reqs = []
    for s, n in zip(lens, news):
        p = rng.integers(0, cfg.vocab_size, s).astype(np.int32)
        if prefix is not None:
            p = np.concatenate([prefix, p])
        reqs.append(Request(prompt=p, max_new=n))
    return reqs


def _contiguous_ref(params, cfg, qm, reqs, max_len=96, **kw):
    """Reference: the contiguous continuous scheduler with unbucketed
    prompts (position-0 placement — the paged engine's placement)."""
    eng = Engine(params, cfg, qm, batch_size=2, max_len=max_len,
                 scheduler="continuous", bucket_prompts=False, **kw)
    return eng.generate(reqs)


# ---------------------------------------------------------------------------
# PagedKV pool layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["none"] + KV_FMTS)
def test_pagedkv_zeros_and_gather(fmt):
    pool = packing.PagedKV.zeros((4, 8, 64), fmt)
    assert pool.page_size == 8 and pool.n_pages == 4
    assert pool.feature_dim == 64
    bt = jnp.asarray([[2, 0], [1, 3]], jnp.int32)
    out = pool.gather_dense(bt)
    assert out.shape == (2, 16, 64)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


@pytest.mark.parametrize("fmt", KV_FMTS)
def test_pagedkv_gather_matches_contiguous_decode(fmt):
    """Gathering pages through a block table reproduces the contiguous
    PackedKV decode of the same logical sequence."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 16, 64)), jnp.float32)  # (B, S, D)
    # pack the two lanes' rows into a shuffled 4-page pool of 8 tokens
    pages = jnp.concatenate([x[0].reshape(2, 8, 64),
                             x[1].reshape(2, 8, 64)])           # (4, 8, 64)
    perm = [2, 0, 3, 1]
    c, s = packing.kv_encode(pages[jnp.asarray(perm)], fmt)
    pool = packing.PagedKV(c, s, fmt, "float32")
    inv = [perm.index(i) for i in range(4)]
    bt = jnp.asarray([[inv[0], inv[1]], [inv[2], inv[3]]], jnp.int32)
    want = packing.PackedKV.from_dense(x, fmt).to_dense()
    got = pool.gather_dense(bt)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-6, rtol=1e-6)


# ---------------------------------------------------------------------------
# Paged flash-decode kernel vs oracle
# ---------------------------------------------------------------------------

def _paged_kv(seed, n_pages, P, D, fmt):
    rng = np.random.default_rng(seed)
    k = jnp.asarray(rng.normal(size=(n_pages, P, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n_pages, P, D)), jnp.float32)
    kc, ks = packing.kv_encode(k, fmt)
    vc, vs = packing.kv_encode(v, fmt)
    return kc, ks, vc, vs


@pytest.mark.parametrize("fmt", KV_FMTS)
@pytest.mark.parametrize("gqa", [1, 4])
def test_paged_kernel_matches_ref(fmt, gqa):
    kvh, Dh = 2, 32
    H = kvh * gqa
    kc, ks, vc, vs = _paged_kv(0, 6, 16, kvh * Dh, fmt)
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(2, H, Dh)), jnp.float32)
    bt = jnp.asarray([[2, 0, 4], [1, 3, 0]], jnp.int32)
    pos = jnp.asarray([29, 40], jnp.int32)
    fill = pos + 1
    y = ops.mx_flash_decode_paged(q, kc, ks, vc, vs, bt, pos, fill, fmt,
                                  interpret=True)
    yr = ops.mx_attention_paged_ref(q, kc, ks, vc, vs, bt, pos, fill, fmt)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("window", [8, 24])
def test_paged_kernel_sliding_window(window):
    kc, ks, vc, vs = _paged_kv(2, 5, 16, 64, "mxfp8")
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(2, 4, 32)), jnp.float32)
    bt = jnp.asarray([[0, 2, 3], [4, 1, 0]], jnp.int32)
    pos = jnp.asarray([35, 47], jnp.int32)
    y = ops.mx_flash_decode_paged(q, kc, ks, vc, vs, bt, pos, pos + 1,
                                  "mxfp8", window=window, interpret=True)
    yr = ops.mx_attention_paged_ref(q, kc, ks, vc, vs, bt, pos, pos + 1,
                                    "mxfp8", window=window)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               atol=2e-5, rtol=2e-5)


def test_paged_kernel_matches_contiguous_kernel():
    """A paged pool with scattered tables computes the same attention as
    the contiguous kernel on the gathered logical cache — indirection
    changes memory addressing, not values."""
    fmt = "mxfp8"
    kc, ks, vc, vs = _paged_kv(4, 6, 16, 64, fmt)
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(2, 4, 32)), jnp.float32)
    bt = np.asarray([[5, 2, 1], [0, 3, 4]], np.int32)
    pos = jnp.asarray([33, 46], jnp.int32)
    y = ops.mx_flash_decode_paged(q, kc, ks, vc, vs, jnp.asarray(bt),
                                  pos, pos + 1, fmt, interpret=True)

    def flat(pool):
        return jnp.asarray(np.asarray(pool)[bt].reshape(2, 48, -1))

    yc = ops.mx_flash_decode(q, flat(kc), flat(ks), flat(vc), flat(vs),
                             pos, pos + 1, fmt, bs=16, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yc),
                               atol=2e-5, rtol=2e-5)


def test_pick_chunk_explicit_override():
    """The satellite fix: an explicit chunk width drives a multi-chunk
    grid in interpret mode (the default collapses to one chunk there)
    and a non-dividing width raises instead of being silently halved."""
    assert _pick_chunk(64, 16, explicit=True) == 16
    assert _pick_chunk(64, 128, explicit=True) == 64   # clamped to S
    with pytest.raises(ValueError, match="does not divide"):
        _pick_chunk(64, 24, explicit=True)
    assert _pick_chunk(48, 32) == 16                   # legacy halving
    # multi-chunk interpret run agrees with the single-chunk default
    kc, ks, vc, vs = _paged_kv(6, 1, 64, 64, "mxfp8")
    q = jnp.asarray(np.random.default_rng(7).normal(size=(1, 4, 32)),
                    jnp.float32)
    pos = jnp.asarray([50], jnp.int32)
    args = (q, kc.reshape(1, 64, -1), ks.reshape(1, 64, -1),
            vc.reshape(1, 64, -1), vs.reshape(1, 64, -1), pos, pos + 1,
            "mxfp8")
    y_multi = ops.mx_flash_decode(*args, bs=16, interpret=True)
    y_single = ops.mx_flash_decode(*args, interpret=True)
    np.testing.assert_allclose(np.asarray(y_multi), np.asarray(y_single),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# BlockAllocator lifecycle
# ---------------------------------------------------------------------------

def test_allocator_alloc_free_refcount():
    al = BlockAllocator(6, 32, reserved=1)
    assert al.capacity == 5 and al.available == 5 and al.in_use == 0
    pages = al.alloc(3)
    assert sorted(pages) == [1, 2, 3]
    assert al.in_use == 3
    al.incref(pages[0])
    al.decref(pages[0])
    assert al.in_use == 3                  # still referenced once
    for p in pages:
        al.decref(p)
    assert al.in_use == 0 and al.available == 5
    with pytest.raises(ValueError, match="decref"):
        al.decref(pages[0])


def test_allocator_exhaustion_returns_none():
    al = BlockAllocator(4, 32, reserved=1)
    assert al.alloc(4) is None             # capacity is 3
    got = al.alloc(3)
    assert len(got) == 3
    assert al.alloc(1) is None             # nothing left, nothing cached


def test_allocator_register_cached_revive_and_lru_evict():
    al = BlockAllocator(5, 32, reserved=1)
    a, b = al.alloc(2)
    al.register(b"ha", a)
    al.register(b"hb", b)
    al.decref(a)
    al.decref(b)
    # both cached (evictable but resident), nothing free
    assert al.in_use == 0 and al.available == 4 and al.resident == 2
    # a prefix hit revives a cached page without allocation
    assert al.lookup(b"ha") == a
    al.incref(a)
    assert al.in_use == 1
    # pressure: 3 fresh pages = 2 free + evict b (LRU), never a (referenced)
    got = al.alloc(3)
    assert b in got and a not in got
    assert al.evicted == 1 and al.lookup(b"hb") is None
    assert al.lookup(b"ha") == a           # survivor stays registered


def test_allocator_first_registration_wins():
    al = BlockAllocator(4, 32)
    a, b = al.alloc(2)
    assert al.register(b"h", a) == a
    assert al.register(b"h", b) == a       # duplicate content: a kept
    al.decref(b)                           # unregistered -> free list
    al.decref(a)                           # registered -> cached
    assert al.lookup(b"h") == a


# ---------------------------------------------------------------------------
# Engine guard rails
# ---------------------------------------------------------------------------

def test_paged_rejects_recurrent_families_at_construction():
    """The guard fires at Engine construction — before any params are
    touched or any prefill runs — with a message naming the fix."""
    from repro import configs
    hy = configs.get_reduced("recurrentgemma-2b")
    with pytest.raises(ValueError, match="ring-buffer.*contiguous"):
        Engine(None, hy, QuantMode.off(), kv_layout="paged",
               scheduler="continuous")


def test_paged_rejects_ssm_at_construction():
    from repro import configs
    sm = configs.get_reduced("mamba2-130m")
    with pytest.raises(ValueError, match="ring-buffer.*contiguous"):
        Engine(None, sm, QuantMode.off(), kv_layout="paged",
               scheduler="wave")


def test_paged_requires_continuous_scheduler():
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    with pytest.raises(ValueError, match="continuous"):
        Engine(params, cfg, QuantMode.off(), kv_layout="paged",
               scheduler="wave")


def test_paged_page_size_validation():
    cfg = _cfg()
    with pytest.raises(ValueError, match="32-block"):
        Engine(None, cfg, QuantMode.off(), kv_layout="paged",
               scheduler="continuous", page_size=24)
    with pytest.raises(ValueError, match="chunk-aligned"):
        Engine(None, _cfg(attn_chunk=24), QuantMode.off(),
               kv_layout="paged", scheduler="continuous", page_size=32)
    with pytest.raises(ValueError, match="scrap page"):
        Engine(None, cfg, QuantMode.off(), kv_layout="paged",
               scheduler="continuous", max_len=64, page_size=32,
               n_pages=2)


def test_paged_rejects_oversized_request():
    from repro.serving.policy import RequestState
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                 scheduler="continuous", kv_layout="paged", page_size=32)
    req = eng.submit(Request(prompt=np.zeros(60, np.int32), max_new=8))
    done = eng.drain()
    assert done == [req]
    assert req.state is RequestState.FAILED
    assert "never fit" in req.error
    # rejection happened before any page was touched
    assert eng._alloc.in_use == 0
    eng._alloc.check()


# ---------------------------------------------------------------------------
# End-to-end paged serving: parity with the contiguous scheduler
# ---------------------------------------------------------------------------

LENS = [5, 16, 23, 9, 17, 31]
NEWS = [4, 9, 6, 12, 3, 8]


def test_paged_bit_identical_to_contiguous_dense():
    """kv_cache='none': the paged engine reproduces the contiguous
    continuous scheduler bit-for-bit on mixed-length traffic (prompt
    placement, chunk grid, and masked-page no-ops all line up)."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    qm = QuantMode.off()
    ref = _contiguous_ref(params, cfg, qm,
                          _requests(cfg, LENS, NEWS, seed=7))
    eng = Engine(params, cfg, qm, batch_size=2, max_len=96,
                 scheduler="continuous", kv_layout="paged", page_size=32)
    got = eng.generate(_requests(cfg, LENS, NEWS, seed=7))
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(g.out, r.out)
    st = eng.stats()
    assert st["kv_layout"] == "paged"
    assert st["blocks_in_use"] == 0          # all released after drain
    assert st["prefix_hit_tokens"] == 0      # disjoint prompts


def test_paged_quantized_matches_contiguous_quantized():
    """mxfp8 cache: paged serving matches the contiguous engine serving
    the same quantized cache (same quantize-on-append points, same
    values) token-for-token, and stays within the pinned tolerance of
    the dense cache by the existing kv-cache tests."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    qm = QuantMode.mxfp4(t3=True)
    ref = _contiguous_ref(params, cfg, qm,
                          _requests(cfg, LENS, NEWS, seed=3),
                          kv_cache="mxfp8")
    eng = Engine(params, cfg, qm, batch_size=2, max_len=96,
                 scheduler="continuous", kv_layout="paged", page_size=32,
                 kv_cache="mxfp8")
    got = eng.generate(_requests(cfg, LENS, NEWS, seed=3))
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(g.out, r.out)


def test_paged_fused_backend_runs_paged_kernel():
    """backend='fused' + quantized pool: decode goes through the paged
    flash-decode kernel (block-table grid). Greedy outputs match the
    ref-backend paged engine, whose decode-in-place reads identical
    dequantized values."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    lens, news = [9, 21, 14], [6, 5, 8]
    outs = {}
    for backend in ("ref", "fused"):
        eng = Engine(params, cfg, QuantMode.off(), batch_size=2,
                     max_len=96, scheduler="continuous",
                     kv_layout="paged", page_size=32, kv_cache="mxfp8",
                     backend=backend)
        outs[backend] = eng.generate(_requests(cfg, lens, news, seed=5))
    for r, g in zip(outs["ref"], outs["fused"]):
        np.testing.assert_array_equal(g.out, r.out)


def test_paged_moe_matches_contiguous():
    cfg = _moe_cfg()
    params = api.init(jax.random.PRNGKey(1), cfg)
    qm = QuantMode.off()
    lens, news = [6, 18, 11, 25], [5, 4, 7, 3]
    ref = _contiguous_ref(params, cfg, qm,
                          _requests(cfg, lens, news, seed=2))
    eng = Engine(params, cfg, qm, batch_size=2, max_len=96,
                 scheduler="continuous", kv_layout="paged", page_size=32)
    got = eng.generate(_requests(cfg, lens, news, seed=2))
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(g.out, r.out)


# ---------------------------------------------------------------------------
# Prefix caching: hit/miss parity, single prefill, copy-on-write, eviction
# ---------------------------------------------------------------------------

def test_prefix_hit_parity_and_single_prefill():
    """>= 2 requests sharing a system prompt: the shared pages are
    chunk-prefilled exactly once (step counters prove it), later
    admissions reuse them by reference, and outputs stay identical to a
    cold engine serving each request without sharing."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    qm = QuantMode.off()
    P, C = 32, cfg.attn_chunk
    rng = np.random.default_rng(9)
    sys_prompt = rng.integers(0, cfg.vocab_size, 2 * P).astype(np.int32)
    tails = [7, 12, 3, 20]
    news = [6, 4, 8, 5]
    reqs = _requests(cfg, tails, news, seed=4, prefix=sys_prompt)

    # cold reference: every request served alone by a fresh paged engine
    # (prefix cache empty each time -> pure miss path)
    ref_out = []
    for r in reqs:
        cold = Engine(params, cfg, qm, batch_size=2, max_len=128,
                      scheduler="continuous", kv_layout="paged",
                      page_size=P)
        ref_out.append(cold.generate(
            [Request(prompt=r.prompt.copy(), max_new=r.max_new)])[0].out)
        assert cold.stats()["prefix_hit_tokens"] == 0   # miss path

    eng = Engine(params, cfg, qm, batch_size=2, max_len=128,
                 scheduler="continuous", kv_layout="paged", page_size=P)
    got = eng.generate(reqs)
    for out, g in zip(ref_out, got):
        np.testing.assert_array_equal(g.out, out)
    st = eng.stats()
    # first admission prefills prefix + tail; the other three skip the
    # two shared pages and prefill only their tail chunks
    assert st["prefix_hit_tokens"] == 3 * 2 * P
    expect = sum(-(-(2 * P + t) // C) for t in tails[:1]) \
        + sum(-(-(2 * P + t - 2 * P) // C) for t in tails[1:])
    assert st["prefill_chunk_steps"] == expect
    assert st["blocks_in_use"] == 0


def test_prefix_copy_on_write_partial_page():
    """A prompt that is exactly its cached pages (s % P == 0, full
    match): the final chunk must re-run for logits, which would rewrite
    a shared page — admission copies it first. Outputs are stable across
    repeated serves and the cached bytes survive for later requests."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    P, C = 32, cfg.attn_chunk
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, 2 * P).astype(np.int32)
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=96,
                 scheduler="continuous", kv_layout="paged", page_size=P)
    outs, hits = [], []
    for _ in range(3):
        outs.append(eng.generate(
            [Request(prompt=prompt.copy(), max_new=5)])[0].out)
        hits.append(eng.stats()["prefix_hit_tokens"])
    np.testing.assert_array_equal(outs[0], outs[1])
    np.testing.assert_array_equal(outs[0], outs[2])
    # each warm admission reuses one full page by reference plus P - C
    # tokens of the copied page; only the final chunk re-runs
    per_hit = 2 * P - C
    assert hits == [0, per_hit, 2 * per_hit]
    assert eng.stats()["prefill_chunk_steps"] == (2 * P // C) + 2


def test_prefix_cache_survives_interleaved_traffic():
    """Shared pages stay valid while other requests allocate, write, and
    free pages around them: serve A (registers), B (different prompt),
    then A again — identical outputs."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(13)
    pa = rng.integers(0, cfg.vocab_size, 48).astype(np.int32)
    pb = rng.integers(0, cfg.vocab_size, 37).astype(np.int32)
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=96,
                 scheduler="continuous", kv_layout="paged", page_size=32)
    a1 = eng.generate([Request(prompt=pa.copy(), max_new=6)])[0].out
    eng.generate([Request(prompt=pb.copy(), max_new=9)])
    a2 = eng.generate([Request(prompt=pa.copy(), max_new=6)])[0].out
    np.testing.assert_array_equal(a1, a2)
    assert eng.stats()["prefix_hit_tokens"] > 0


def test_lru_eviction_under_pool_pressure():
    """A pool too small to cache every prompt: cached prefix pages are
    LRU-evicted to serve new traffic, correctness is unaffected, and the
    eviction counter reports it."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    qm = QuantMode.off()
    lens = [40, 44, 38, 42, 35, 41]
    news = [6, 4, 8, 5, 7, 4]
    reqs = _requests(cfg, lens, news, seed=6)
    ref = _contiguous_ref(params, cfg, qm,
                          _requests(cfg, lens, news, seed=6), max_len=64)
    # capacity 4 pages; every request needs 2 -> finished prompts' cached
    # pages must be evicted to admit the next ones
    eng = Engine(params, cfg, qm, batch_size=2, max_len=64,
                 scheduler="continuous", kv_layout="paged", page_size=32,
                 n_pages=5)
    got = eng.generate(reqs)
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(g.out, r.out)
    assert eng.stats()["blocks_evicted"] > 0


def test_pool_exhaustion_backpressure():
    """A pool that fits only one request at a time: admissions queue up
    (backpressure instead of failure), every request still completes,
    and block accounting returns to zero."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                 scheduler="continuous", kv_layout="paged", page_size=32,
                 n_pages=3)
    lens = [40, 44, 38, 42]
    news = [8, 6, 7, 5]
    reqs = _requests(cfg, lens, news, seed=8)
    done = eng.generate(reqs)
    assert all(len(r.out) == n for r, n in zip(done, news))
    st = eng.stats()
    assert st["blocks_in_use"] == 0 and st["admitted"] == len(reqs)


def test_paged_stats_and_resident_bytes():
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=96,
                 scheduler="continuous", kv_layout="paged", page_size=32)
    for key in ("prefix_hit_tokens", "blocks_in_use", "blocks_evicted",
                "prefill_chunk_steps", "kv_layout"):
        assert key in eng.stats()
    assert eng.kv_bytes_resident() == 0            # pool not built yet
    eng.generate(_requests(cfg, [20], [4], seed=1))
    resident = eng.kv_bytes_resident()
    total = sum(int(a.size) * a.dtype.itemsize
                for a in jax.tree.leaves(eng._cache))
    # after one short request: scrap page + its cached prompt page(s),
    # far below the full pool
    assert 0 < resident < total
    # contiguous engines report the whole reserved pool, admission
    # scratch lane included
    ref = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=96,
                 scheduler="continuous")
    ref.generate(_requests(cfg, [20], [4], seed=1))
    leaves = jax.tree.leaves((ref._cache, ref._slot_cache))
    assert ref.kv_bytes_resident() == sum(
        int(a.size) * a.dtype.itemsize for a in leaves)


def test_paged_streaming_on_token():
    """The streaming callback path is layout-independent."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=96,
                 scheduler="continuous", kv_layout="paged", page_size=32)
    reqs = _requests(cfg, [12, 26], [5, 7], seed=2)
    streamed = {i: [] for i in range(len(reqs))}
    for i, r in enumerate(reqs):
        r.on_token = streamed[i].append
        eng.submit(r)
    eng.drain()
    for i, r in enumerate(reqs):
        assert list(r.out) == streamed[i]
