"""Serving engine: greedy generation matches teacher-forced argmax; the
continuous-batching scheduler is token-identical per request to the wave
engine; slot/compile accounting, EOS handling, bucketing edge cases, and
scheduler starvation behavior."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig
from repro.core.quantize import QuantMode
from repro.models import api
from repro.serving.engine import Engine, Request


def _cfg(**kw):
    base = dict(name="tiny", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                attn_chunk=16)
    base.update(kw)
    return ArchConfig(**base)


def _moe_cfg(**kw):
    base = dict(name="tiny-moe", family="moe", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=128,
                n_experts=4, top_k=2, n_shared_experts=1, attn_chunk=16,
                # capacity >= tokens*top_k: expert dispatch is drop-free, so
                # chunked prefill is exactly equivalent to full prefill
                # (see docs/serving.md on MoE capacity and parity)
                capacity_factor=4.0)
    base.update(kw)
    return ArchConfig(**base)


def _mixed_requests(cfg, lens, news, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, s)
                    .astype(np.int32), max_new=n)
            for s, n in zip(lens, news)]


def _wave_per_request(params, cfg, qm, reqs, max_len=64, **kw):
    """Reference: the wave engine serving each request alone (B=1 waves) —
    identical padding semantics to a continuous slot."""
    eng = Engine(params, cfg, qm, batch_size=1, max_len=max_len, **kw)
    return [eng.generate([r])[0] for r in reqs]


# ---------------------------------------------------------------------------
# Wave scheduler (existing behavior)
# ---------------------------------------------------------------------------

def test_engine_matches_teacher_forcing():
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 128, 16).astype(np.int32) for _ in range(2)]
    reqs = [Request(prompt=p, max_new=8) for p in prompts]
    done = eng.generate(reqs)

    # reference: repeated full forward + argmax
    for r in done:
        seq = list(r.prompt)
        ref = []
        for _ in range(8):
            logits = api.forward(params, cfg,
                                 jnp.asarray([seq], jnp.int32))
            nxt = int(jnp.argmax(logits[0, -1]))
            ref.append(nxt)
            seq.append(nxt)
        assert list(r.out) == ref, (list(r.out), ref)


def test_engine_quantized_runs():
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(1), cfg)
    eng = Engine(params, cfg, QuantMode.mxfp4(t3=False), batch_size=2,
                 max_len=64)
    stats = eng.throughput(n_requests=2, prompt_len=8, max_new=4)
    assert stats["tokens"] == 8 and stats["tok_per_s"] > 0


# ---------------------------------------------------------------------------
# Continuous scheduler: per-request token parity with the wave engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("qm", [QuantMode.off(), QuantMode.mxfp4(t3=True)],
                         ids=["fp", "mxfp4-t3"])
def test_continuous_matches_wave_per_request(qm):
    """Mixed prompt lengths and decode budgets: every request's tokens are
    bit-identical to the wave engine serving it (chunked prefill and
    per-slot decode positions change nothing per lane)."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    lens = [5, 16, 23, 9, 17, 31]   # crosses chunk boundaries both ways
    news = [4, 9, 6, 12, 3, 8]
    ref = _wave_per_request(params, cfg, qm,
                            _mixed_requests(cfg, lens, news))
    eng = Engine(params, cfg, qm, batch_size=2, max_len=64,
                 scheduler="continuous")
    got = eng.generate(_mixed_requests(cfg, lens, news))
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(g.out, r.out)


def test_continuous_matches_wave_moe():
    """MoE: slots share the routed-expert dispatch each decode step; with
    drop-free capacity the outputs stay per-request identical (multi-chunk
    prompts included)."""
    cfg = _moe_cfg()
    params = api.init(jax.random.PRNGKey(1), cfg)
    lens = [7, 16, 21, 12, 37]
    news = [5, 8, 3, 10, 6]
    ref = _wave_per_request(params, cfg, QuantMode.off(),
                            _mixed_requests(cfg, lens, news, seed=3))
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                 scheduler="continuous")
    got = eng.generate(_mixed_requests(cfg, lens, news, seed=3))
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(g.out, r.out)


def _artifact(tmp_path, cfg, name, seed=0):
    from repro.artifacts import export_artifact
    from repro.core import ptq
    from repro.data import synthetic
    params = api.init(jax.random.PRNGKey(seed), cfg)
    src = synthetic.make_source(cfg, 4, 32, 0)
    calib = [{k: jnp.asarray(v) for k, v in src.batch(i).items()}
             for i in range(2)]
    res = ptq.apply_method("rtn", params, cfg, calib, fmt="mxfp4")
    out = tmp_path / name
    export_artifact(res, cfg, out)
    return out


@pytest.mark.parametrize("backend", ["ref", "fused"])
def test_continuous_matches_wave_artifact(tmp_path, backend):
    """Artifact-served packed weights, both execution backends: the
    continuous scheduler reproduces the wave engine token-for-token."""
    cfg = _cfg(attn_chunk=16)
    out = _artifact(tmp_path, cfg, "eng")
    lens = [9, 16, 21]
    news = [6, 3, 8]
    wave = Engine.from_artifact(out, batch_size=1, max_len=64,
                                backend=backend)
    ref = [wave.generate([r])[0]
           for r in _mixed_requests(cfg, lens, news, seed=7)]
    cont = Engine.from_artifact(out, batch_size=2, max_len=64,
                                backend=backend, scheduler="continuous")
    assert cont.qm.backend == backend
    got = cont.generate(_mixed_requests(cfg, lens, news, seed=7))
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(g.out, r.out)


def test_continuous_matches_wave_moe_artifact(tmp_path):
    """Artifact-served MoE (expert-stacked packed weights) under the fused
    backend: single-chunk prompts guarantee the chunked prefill runs the
    exact shapes of the wave prefill (capacity buffers included)."""
    cfg = _moe_cfg(capacity_factor=1.25)   # production-style capacity
    out = _artifact(tmp_path, cfg, "moe", seed=1)
    lens = [6, 16, 11]                     # all within one 16-token chunk
    news = [5, 4, 7]
    wave = Engine.from_artifact(out, batch_size=1, max_len=64,
                                backend="fused")
    ref = [wave.generate([r])[0]
           for r in _mixed_requests(cfg, lens, news, seed=11)]
    cont = Engine.from_artifact(out, batch_size=2, max_len=64,
                                backend="fused", scheduler="continuous")
    got = cont.generate(_mixed_requests(cfg, lens, news, seed=11))
    for r, g in zip(ref, got):
        np.testing.assert_array_equal(g.out, r.out)


# ---------------------------------------------------------------------------
# Slot reuse + compile accounting
# ---------------------------------------------------------------------------

def test_continuous_slot_reuse_and_compile_counts():
    """Serving many mixed-length requests through few slots must cost one
    chunked-prefill compile and one decode compile, total."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                 scheduler="continuous")
    lens = [5, 16, 23, 9, 17, 31, 12, 3]
    news = [4, 9, 6, 12, 3, 8, 2, 5]
    done = eng.generate(_mixed_requests(cfg, lens, news))
    assert all(len(r.out) == n for r, n in zip(done, news))
    stats = eng.stats()
    assert stats["admitted"] == len(lens) > eng.B      # slots recycled
    assert stats["prefill_chunk_compiles"] == 1        # one jit signature
    assert stats["decode_compiles"] == 1               # one decode step fn
    assert stats["prefill_compiles"] == 0              # wave path unused
    assert 0.0 < stats["decode_utilization"] <= 1.0


def test_continuous_higher_utilization_than_wave():
    """On mixed-length traffic the continuous scheduler wastes fewer
    decode slot-steps than static waves (the BENCH_serving metric)."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    lens = [4, 20, 8, 28, 6, 16, 10, 24]
    news = [2, 12, 4, 10, 3, 8, 2, 12]
    wave = Engine(params, cfg, QuantMode.off(), batch_size=4, max_len=64)
    wave.generate(_mixed_requests(cfg, lens, news))
    cont = Engine(params, cfg, QuantMode.off(), batch_size=4, max_len=64,
                  scheduler="continuous")
    cont.generate(_mixed_requests(cfg, lens, news))
    wu = wave.stats()["decode_utilization"]
    cu = cont.stats()["decode_utilization"]
    assert cu > wu, (cu, wu)


# ---------------------------------------------------------------------------
# Bucketing edge cases
# ---------------------------------------------------------------------------

def test_bucket_len_edge_cases():
    cfg = _cfg(attn_chunk=16)
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, QuantMode.off(), batch_size=1, max_len=32)
    # prompt exactly at the chunk boundary: no rounding, no backoff
    assert eng._bucket_len(16, max_new=8) == 16
    assert eng._bucket_len(32, max_new=0) == 32
    # one past the boundary: bucket would overflow the cache -> raw length
    assert eng._bucket_len(17, max_new=8) == 17
    # fits -> bucketed
    assert eng._bucket_len(17, max_new=0) == 32
    # max_new overflowing max_len: bucketing backs off all the way to the
    # raw length (the overflow itself is the caller's problem)
    assert eng._bucket_len(30, max_new=40) == 30
    # degenerate prompt
    assert eng._bucket_len(1, max_new=4) == 16


def test_continuous_rejects_oversized_request():
    """A request that cannot fit prompt + budget in the KV pool lands in
    a descriptive terminal FAILED state at admission — it must neither
    corrupt the cache nor requeue forever (head-of-line blocking)."""
    from repro.serving.policy import RequestState
    cfg = _cfg(attn_chunk=16)
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, QuantMode.off(), batch_size=1, max_len=32,
                 scheduler="continuous")
    rng = np.random.default_rng(0)
    big = Request(prompt=rng.integers(0, 128, 30).astype(np.int32),
                  max_new=40)
    small = Request(prompt=rng.integers(0, 128, 8).astype(np.int32),
                    max_new=4)
    eng.generate([big, small])
    assert big.state is RequestState.FAILED
    assert "never fit" in big.error and "max_len" in big.error
    assert big.out is not None and len(big.out) == 0
    # the doomed request must not block the one behind it
    assert small.state is RequestState.FINISHED
    assert len(small.out) == 4
    assert eng.stats()["rejected_never_fit"] == 1


def test_continuous_zero_budget_request():
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                 scheduler="continuous")
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, 128, 8).astype(np.int32),
                    max_new=m) for m in (0, 1, 3)]
    done = eng.generate(reqs)
    assert [len(r.out) for r in done] == [0, 1, 3]


def test_wave_zero_budget_counters_stay_nonnegative():
    """A max_new=0 wave runs no decode steps — counters must not go
    negative (and utilization must stay well-defined)."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64)
    rng = np.random.default_rng(0)
    done = eng.generate([Request(prompt=rng.integers(0, 128, 8)
                                 .astype(np.int32), max_new=0)])
    assert len(done[0].out) == 0
    s = eng.stats()
    assert s["decode_steps"] == 0 and s["slot_steps"] == 0
    assert s["decode_utilization"] == 0.0


def test_throughput_reports_per_run_counters():
    """throughput() on a previously used engine must report the synthetic
    run's own steps/utilization, not a blend with earlier traffic
    (compile counts stay cumulative — the jit cache is engine-wide)."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                 scheduler="continuous")
    rng = np.random.default_rng(0)
    # mixed earlier traffic with imperfect utilization
    eng.generate(_mixed_requests(cfg, [5, 23, 9], [2, 9, 4]))
    stats = eng.throughput(n_requests=2, prompt_len=8, max_new=6)
    assert stats["admitted"] == 2
    assert stats["useful_decode_tokens"] == 2 * 5
    # uniform traffic fills both lanes every step of the run
    assert stats["decode_utilization"] == 1.0
    assert eng.stats()["decode_utilization"] < 1.0    # cumulative differs


# ---------------------------------------------------------------------------
# Starvation: a long request must not block short ones
# ---------------------------------------------------------------------------

def test_continuous_no_starvation():
    """With one slot pinned by a long request, short requests must flow
    through the remaining slots and complete first — under the wave
    scheduler they would wait for the whole wave."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                 scheduler="continuous")
    rng = np.random.default_rng(0)
    long_req = Request(prompt=rng.integers(0, 128, 8).astype(np.int32),
                       max_new=30)
    shorts = [Request(prompt=rng.integers(0, 128, 6).astype(np.int32),
                      max_new=3) for _ in range(4)]
    eng.submit(long_req)
    for r in shorts:
        eng.submit(r)
    completion_order = eng.drain()
    assert completion_order[-1] is long_req          # shorts all finished first
    assert all(len(r.out) == 3 for r in shorts)
    assert len(long_req.out) == 30


# ---------------------------------------------------------------------------
# EOS + streaming API
# ---------------------------------------------------------------------------

def test_eos_stops_continuous_and_trims_wave():
    """eos_id: the wave engine trims outputs at the first EOS; the
    continuous engine stops decoding the slot the step EOS is emitted —
    both yield the same (truncated) token sequence."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, 128, 12).astype(np.int32)
    # find a token this model actually emits mid-sequence
    probe = Engine(params, cfg, QuantMode.off(), batch_size=1, max_len=64)
    full = probe.generate([Request(prompt=prompt.copy(), max_new=10)])[0]
    eos = int(full.out[4])
    first = int(np.flatnonzero(full.out == eos)[0])

    wave = Engine(params, cfg, QuantMode.off(), batch_size=1, max_len=64,
                  eos_id=eos)
    wr = wave.generate([Request(prompt=prompt.copy(), max_new=10)])[0]
    cont = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                  scheduler="continuous", eos_id=eos)
    cr = cont.generate([Request(prompt=prompt.copy(), max_new=10)])[0]
    assert len(wr.out) == first + 1 and wr.out[-1] == eos
    np.testing.assert_array_equal(cr.out, wr.out)
    # the freed slot budget is real: fewer decode steps than max_new
    assert cont.stats()["decode_steps"] < 10


def test_streaming_submit_step_on_token():
    """submit/step streaming: tokens arrive through on_token callbacks as
    the scheduler steps, and completed requests come back from step()."""
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64,
                 scheduler="continuous")
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, 128, s).astype(np.int32),
                    max_new=n) for s, n in [(5, 4), (17, 6), (9, 3)]]
    streams = []
    for r in reqs:
        chunks = []
        r.on_token = chunks.append
        streams.append(chunks)
        eng.submit(r)
    done = []
    steps = 0
    while len(done) < len(reqs):
        done.extend(eng.step())
        steps += 1
        assert steps < 100, "scheduler failed to converge"
    for r, s in zip(reqs, streams):
        assert list(r.out) == s                     # streamed == final
    # wave scheduler supports the same surface (tokens at wave end)
    wave = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64)
    got = []
    r = Request(prompt=rng.integers(0, 128, 8).astype(np.int32),
                max_new=4, on_token=got.append)
    wave.submit(r)
    assert wave.step() == [r] and got == list(r.out)
    assert wave.drain() == []                       # idempotent when idle
