"""Serving engine: greedy generation matches teacher-forced argmax."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.quantize import QuantMode
from repro.models import api
from repro.serving.engine import Engine, Request


def _cfg():
    return ArchConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                      attn_chunk=16)


def test_engine_matches_teacher_forcing():
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(0), cfg)
    eng = Engine(params, cfg, QuantMode.off(), batch_size=2, max_len=64)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, 128, 16).astype(np.int32) for _ in range(2)]
    reqs = [Request(prompt=p, max_new=8) for p in prompts]
    done = eng.generate(reqs)

    # reference: repeated full forward + argmax
    for r in done:
        seq = list(r.prompt)
        ref = []
        for _ in range(8):
            logits = api.forward(params, cfg,
                                 jnp.asarray([seq], jnp.int32))
            nxt = int(jnp.argmax(logits[0, -1]))
            ref.append(nxt)
            seq.append(nxt)
        assert list(r.out) == ref, (list(r.out), ref)


def test_engine_quantized_runs():
    cfg = _cfg()
    params = api.init(jax.random.PRNGKey(1), cfg)
    eng = Engine(params, cfg, QuantMode.mxfp4(t3=False), batch_size=2,
                 max_len=64)
    stats = eng.throughput(n_requests=2, prompt_len=8, max_new=4)
    assert stats["tokens"] == 8 and stats["tok_per_s"] > 0
