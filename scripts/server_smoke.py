"""CI server smoke: boot the demo HTTP server as a real subprocess,
drive it over real HTTP, SIGTERM it, and assert a clean drain.

    PYTHONPATH=src python scripts/server_smoke.py

What it checks (the process-boundary contract of docs/server.md — the
in-process coverage lives in tests/test_server.py):

1. the server subprocess comes up and prints its bound port;
2. ``/healthz`` 200, ``/readyz`` 200, ``/metrics`` non-empty and
   carrying the serving counters;
3. one streamed generation over real HTTP completes (``event: done``
   with state ``finished``);
4. SIGTERM: exit code 0, drain report printed with every request
   terminal (``sum(terminal) == submitted``) and the allocator clean —
   zero leaked pages.

Exit 0 on success, 1 with a diagnosis otherwise.
"""
from __future__ import annotations

import json
import os
import pathlib
import re
import signal
import socket
import subprocess
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parent.parent


def http(port: int, method: str, path: str, body: dict | None = None,
         timeout_s: float = 60.0):
    """(code, headers, payload) over one blocking socket."""
    data = b"" if body is None else json.dumps(body).encode()
    with socket.create_connection(("127.0.0.1", port),
                                  timeout=timeout_s) as s:
        s.sendall((f"{method} {path} HTTP/1.1\r\nHost: s\r\n"
                   f"Content-Length: {len(data)}\r\n\r\n").encode() + data)
        raw = b""
        while chunk := s.recv(65536):
            raw += chunk
    head, _, payload = raw.partition(b"\r\n\r\n")
    headers = {}
    for line in head.split(b"\r\n")[1:]:
        if b": " in line:
            k, v = line.decode().split(": ", 1)
            headers[k.lower()] = v
    return int(head.split()[1]), headers, payload


def main() -> int:
    env = dict(os.environ)
    env["PYTHONPATH"] = (str(ROOT / "src")
                         + os.pathsep + env.get("PYTHONPATH", ""))
    proc = subprocess.Popen(
        [sys.executable, "-u", "-m", "repro.serving.server",
         "--port", "0", "--max-queue-depth", "64"],
        stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True, env=env, cwd=ROOT)
    port = None
    lines = []
    try:
        # 1. startup: the port line must appear (compile can take a bit)
        deadline = time.time() + 120
        while time.time() < deadline:
            line = proc.stdout.readline()
            if not line:
                break
            lines.append(line)
            if m := re.search(r"serving on http://[\d.]+:(\d+)", line):
                port = int(m.group(1))
                break
        if port is None:
            print("FAIL: server never printed its port\n" + "".join(lines))
            return 1
        print(f"server up on :{port}")

        # 2. health + readiness + metrics
        code, _, body = http(port, "GET", "/healthz")
        assert code == 200, f"/healthz {code}"
        code, _, body = http(port, "GET", "/readyz")
        assert code == 200, f"/readyz {code}: {body!r}"
        code, _, metrics = http(port, "GET", "/metrics")
        assert code == 200 and metrics, "/metrics empty"
        for needle in (b"serving_requests_submitted_total",
                       b"serving_requests_shed_total",
                       b"serving_supervisor_restarts_total"):
            assert needle in metrics, f"{needle!r} missing from /metrics"
        print(f"healthz/readyz/metrics OK ({len(metrics)}B scrape)")

        # 3. one streamed generation over real HTTP
        code, _, payload = http(port, "POST", "/v1/generate",
                                {"prompt": [1, 2, 3, 4], "max_new": 8,
                                 "stream": True})
        assert code == 200, f"generate {code}"
        text = payload.decode()
        tokens = re.findall(r"^event: token$", text, re.M)
        done = [json.loads(l[5:]) for l in text.splitlines()
                if l.startswith("data:")][-1]
        assert tokens, "no token events streamed"
        assert done["state"] == "finished", done
        assert done["n_tokens"] == 8, done
        print(f"streamed {done['n_tokens']} tokens over SSE "
              f"({len(tokens)} events)")

        # 4. SIGTERM -> clean drain
        proc.send_signal(signal.SIGTERM)
        out, _ = proc.communicate(timeout=60)
        lines.append(out)
        assert proc.returncode == 0, \
            f"server exited {proc.returncode}:\n{out}"
        m = re.search(r"drain report: (\{.*\})", out)
        assert m, f"no drain report in output:\n{out}"
        report = json.loads(m.group(1))
        assert report["clean"], report
        assert report["terminal_sum"] == report["submitted"], report
        assert report["allocator_clean"], report
        print("SIGTERM drain clean: "
              f"submitted={report['submitted']} "
              f"terminal={report['terminal']} "
              f"allocator={report['allocator']}")
        print("server smoke PASS")
        return 0
    except AssertionError as exc:
        print(f"FAIL: {exc}")
        return 1
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()


if __name__ == "__main__":
    sys.exit(main())
