"""CI chaos smoke: replay seeded fault scenarios against the serving
engine and validate the observable story end to end — the exported
trace must contain the preemption/timeout/cancel instants, the metrics
snapshot must carry the terminal-state counters, and quiescence must
leave zero leaked pages (docs/robustness.md).

    PYTHONPATH=src python scripts/chaos_smoke.py

Four scenarios, all deterministic (seeded injector + greedy decode):

1. lifecycle — a tight paged pool where a high-priority arrival
   preempts the running request, a zero-deadline request times out,
   and a queued request is cancelled; traced.
2. nan-isolation — a poisoned decode lane fails only its own request.
3. corruption — a truncated artifact tensor file is rejected with a
   descriptive IntegrityError, not a zip traceback.
4. server-supervisor — the HTTP front end's EngineSupervisor survives
   an injected step failure: the poisoned lane fails terminally, the
   bystander requeues and resumes bit-identically, an over-depth
   submit sheds loudly, and quiescence leaves zero leaked pages.

Exit 0 on success, 1 with a message on the first violated invariant.
"""
import pathlib
import sys
import tempfile
import time

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))

import jax
import numpy as np

from repro.artifacts import IntegrityError, export_artifact, load_artifact
from repro.artifacts.manifest import WEIGHTS_FILE
from repro.configs.base import ArchConfig
from repro.core import ptq
from repro.core.quantize import QuantMode
from repro.models import api
from repro.obs import MetricsRegistry, Tracer, validate_trace
from repro.serving.engine import Engine, Request
from repro.serving.faults import FaultInjector, corrupt_file
from repro.serving.policy import RequestState, SchedulingPolicy, ShedError
from repro.serving.server import EngineSupervisor

CFG = ArchConfig(name="chaos", family="dense", n_layers=2, d_model=64,
                 n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=128,
                 attn_chunk=16)


def _req(n, new, seed, **kw):
    rng = np.random.default_rng(seed)
    return Request(prompt=rng.integers(0, CFG.vocab_size, n)
                   .astype(np.int32), max_new=new, **kw)


def scenario_lifecycle(params):
    tracer, metrics = Tracer(), MetricsRegistry()
    eng = Engine(params, CFG, QuantMode.off(), batch_size=2, max_len=64,
                 scheduler="continuous", kv_layout="paged", page_size=32,
                 n_pages=3, policy=SchedulingPolicy(backoff_base_s=0.001),
                 faults=FaultInjector(seed=0).inject("slow_step", every=6,
                                                     delay_s=0.001),
                 metrics=metrics, tracer=tracer)
    # lo fills the pool (far-future deadline caps its bursts so it is
    # mid-flight when hi arrives); hi preempts it; doomed times out;
    # parked is cancelled while queued.
    lo = _req(40, 10, seed=7, priority=0, deadline_ms=1e7)
    eng.submit(lo)
    eng.step()
    assert lo.state is RequestState.RUNNING, "lo never started"
    hi = _req(38, 8, seed=8, priority=5)
    doomed = _req(10, 4, seed=9, deadline_ms=0.0)
    parked = _req(12, 4, seed=10, deadline_ms=1e7)
    for r in (hi, doomed, parked):
        eng.submit(r)
    assert eng.cancel(parked.request_id), "cancel of queued request failed"
    steps = 0
    while eng.busy:
        eng.step()
        steps += 1
        assert steps < 500, "no quiescence"
        eng._alloc.check()

    st = eng.stats()
    assert lo.state is RequestState.FINISHED and lo.preemptions >= 1
    assert hi.state is RequestState.FINISHED
    assert doomed.state is RequestState.TIMED_OUT
    assert parked.state is RequestState.CANCELLED
    assert sum(st["terminal"].values()) == st["submitted"] == 4
    assert st["preemptions"] >= 1
    assert st["blocks_in_use"] == 0, "leaked pages"
    eng._alloc.check()

    with tempfile.TemporaryDirectory() as td:
        evs = validate_trace(eng.tracer.export(f"{td}/chaos_trace.json"))
    names = [e["name"] for e in evs]
    for needle in ("preempt", "timeout", "cancel"):
        assert needle in names, f"trace is missing the {needle!r} instant"
    snap = metrics.snapshot()
    got = {s["labels"]["state"]: s["value"]
           for s in snap["serving_requests_terminal_total"]}
    assert got.get("finished") == 2 and got.get("timed_out") == 1 \
        and got.get("cancelled") == 1, f"terminal counters wrong: {got}"
    assert snap["serving_preemptions_total"][0]["value"] >= 1
    print(f"lifecycle OK: {len(evs)} trace events, terminal={got}, "
          f"{st['preemptions']} preemptions, 0 leaked pages")


def scenario_nan_isolation(params):
    eng = Engine(params, CFG, QuantMode.off(), batch_size=2, max_len=64,
                 scheduler="continuous",
                 faults=FaultInjector(seed=0).inject("nan_logits", at=1,
                                                     lane=0))
    victim, bystander = _req(16, 6, seed=20), _req(24, 6, seed=21)
    ref = Engine(params, CFG, QuantMode.off(), batch_size=2, max_len=64,
                 scheduler="continuous")
    ref_out = ref.generate([_req(24, 6, seed=21)])[0].out
    eng.submit(victim)
    eng.submit(bystander)
    eng.drain()
    assert victim.state is RequestState.FAILED, victim.state
    assert "non-finite" in victim.error
    assert bystander.state is RequestState.FINISHED
    np.testing.assert_array_equal(bystander.out, ref_out)
    assert eng.stats()["nan_guard_trips"] == 1
    print(f"nan-isolation OK: victim failed ({victim.error!r}), "
          f"bystander bit-identical to fault-free run")


def scenario_corruption(params):
    calib_rng = np.random.default_rng(0)
    calib = [{"inputs": calib_rng.integers(0, CFG.vocab_size, (2, 32))}]
    res = ptq.apply_method("rtn", params, CFG, calib, fmt="mxfp4")
    with tempfile.TemporaryDirectory() as td:
        art = pathlib.Path(td) / "art"
        export_artifact(res, CFG, art)
        load_artifact(art)                       # sanity: loads clean
        corrupt_file(art / WEIGHTS_FILE, mode="truncate", seed=1,
                     within=art)
        try:
            load_artifact(art)
        except IntegrityError as e:
            assert "corrupt or truncated" in str(e), str(e)
            print(f"corruption OK: descriptive IntegrityError ({e})")
        else:
            raise AssertionError("truncated artifact loaded silently")


def scenario_server_supervisor(params):
    metrics = MetricsRegistry()
    fi = FaultInjector(seed=0).inject("failed_step", at=2, lane=0)
    eng = Engine(params, CFG, QuantMode.off(), batch_size=2, max_len=64,
                 scheduler="continuous", kv_layout="paged", page_size=32,
                 n_pages=6,
                 policy=SchedulingPolicy(deadline_ms=1e9,  # burst cap on
                                         max_queue_depth=2),
                 metrics=metrics)
    # fault-free twin for the bit-identical-resume assertion
    ref = Engine(params, CFG, QuantMode.off(), batch_size=2, max_len=64,
                 scheduler="continuous", kv_layout="paged", page_size=32,
                 n_pages=6)
    victim = _req(16, 12, seed=30)
    bystander = _req(24, 12, seed=31)
    ref_out = ref.generate([_req(24, 12, seed=31)])[0].out

    sup = EngineSupervisor(eng, faults=fi, worker_poll_s=0.005)
    sup.start()
    try:
        sup.submit(victim)           # -> lane 0: blamed on the 3rd step
        sup.submit(bystander)        # -> lane 1: requeued, then resumed
        try:
            sup.submit(_req(8, 4, seed=32))
        except ShedError as e:
            shed = e.request
        else:
            raise AssertionError("over-depth submit was not shed")
        deadline = time.monotonic() + 30
        while not sup.idle():
            assert time.monotonic() < deadline, "supervisor never quiesced"
            time.sleep(0.01)
    finally:
        sup.stop()

    assert victim.state is RequestState.FAILED, victim.state
    assert "supervisor" in victim.error, victim.error
    assert bystander.state is RequestState.FINISHED, bystander.state
    np.testing.assert_array_equal(bystander.out, ref_out)
    assert shed.state is RequestState.SHED, shed.state
    assert sup.restarts == 1, sup.restarts
    st = sup.stats()
    assert sum(st["terminal"].values()) == st["submitted"] == 3, st
    assert st["blocks_in_use"] == 0, "leaked pages"
    eng._alloc.check()
    snap = metrics.snapshot()
    assert snap["serving_requests_shed_total"][0]["value"] == 1
    assert snap["serving_supervisor_restarts_total"][0]["value"] == 1
    print(f"server-supervisor OK: victim failed ({victim.error!r}), "
          f"bystander resumed bit-identically after restart, 1 shed, "
          f"0 leaked pages")


def main():
    params = api.init(jax.random.PRNGKey(0), CFG)
    scenario_lifecycle(params)
    scenario_nan_isolation(params)
    scenario_corruption(params)
    scenario_server_supervisor(params)
    print("chaos smoke: all scenarios green")
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except AssertionError as e:
        print(f"chaos smoke FAILED: {e}", file=sys.stderr)
        sys.exit(1)
