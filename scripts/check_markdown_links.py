#!/usr/bin/env python
"""Markdown link-and-anchor checker (stdlib only; wired into CI).

    python scripts/check_markdown_links.py README.md ROADMAP.md docs

For every ``[text](target)`` in the given markdown files (directories
are scanned recursively for ``*.md``):

  * relative file targets must exist on disk,
  * ``#anchor`` fragments (bare or on a relative .md target) must match
    a heading in the target file, using GitHub's slugging rules
    (lowercase, punctuation stripped, spaces -> hyphens, duplicate
    slugs suffixed -1, -2, ...),
  * absolute URLs (http/https/mailto) are skipped — CI must not depend
    on the network.

Fenced code blocks and inline code spans are ignored. Exit code 1 and
one ``file:line: message`` per problem on failure.
"""
from __future__ import annotations

import pathlib
import re
import sys

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.*?)\s*#*\s*$")
FENCE_RE = re.compile(r"^(```|~~~)")
CODE_SPAN_RE = re.compile(r"`[^`]*`")
EXTERNAL = ("http://", "https://", "mailto:", "ftp://")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug for a heading line (markdown stripped)."""
    s = re.sub(r"`([^`]*)`", r"\1", heading)           # code spans
    s = re.sub(r"!?\[([^\]]*)\]\([^)]*\)", r"\1", s)   # links -> text
    s = re.sub(r"[*_]", "", s)                         # emphasis markers
    s = s.strip().lower()
    s = re.sub(r"[^\w\- ]", "", s)                     # punctuation
    return s.replace(" ", "-")


def heading_slugs(path: pathlib.Path) -> set:
    """All anchor slugs a markdown file exposes (duplicates suffixed)."""
    counts: dict = {}
    slugs = set()
    in_fence = False
    for line in path.read_text().splitlines():
        if FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if not m:
            continue
        base = github_slug(m.group(2))
        n = counts.get(base, 0)
        counts[base] = n + 1
        slugs.add(base if n == 0 else f"{base}-{n}")
    return slugs


def iter_links(path: pathlib.Path):
    """Yield (lineno, target) for every markdown link outside code."""
    in_fence = False
    for lineno, line in enumerate(path.read_text().splitlines(), 1):
        if FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK_RE.finditer(CODE_SPAN_RE.sub("``", line)):
            yield lineno, m.group(1)


def check_file(path: pathlib.Path, slug_cache: dict) -> list:
    problems = []

    def slugs_of(p: pathlib.Path) -> set:
        key = p.resolve()
        if key not in slug_cache:
            slug_cache[key] = heading_slugs(p)
        return slug_cache[key]

    for lineno, target in iter_links(path):
        if target.startswith(EXTERNAL):
            continue
        frag = None
        if "#" in target:
            target, frag = target.split("#", 1)
        if target:
            dest = (path.parent / target).resolve()
            if not dest.exists():
                problems.append(f"{path}:{lineno}: broken link -> {target}")
                continue
        else:
            dest = path.resolve()
        if frag is not None:
            if dest.is_dir() or dest.suffix.lower() != ".md":
                problems.append(
                    f"{path}:{lineno}: anchor on non-markdown target "
                    f"-> {target}#{frag}")
            elif frag not in slugs_of(dest):
                problems.append(
                    f"{path}:{lineno}: missing anchor -> "
                    f"{target or path.name}#{frag}")
    return problems


def main(argv) -> int:
    files = []
    for arg in argv or ["README.md", "ROADMAP.md", "docs"]:
        p = pathlib.Path(arg)
        if p.is_dir():
            files.extend(sorted(p.rglob("*.md")))
        elif p.exists():
            files.append(p)
        else:
            print(f"{arg}: no such file or directory", file=sys.stderr)
            return 1
    slug_cache: dict = {}
    problems = []
    for f in files:
        problems.extend(check_file(f, slug_cache))
    for msg in problems:
        print(msg, file=sys.stderr)
    print(f"checked {len(files)} markdown files: "
          f"{'OK' if not problems else f'{len(problems)} problem(s)'}")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
