"""Inject the generated §Roofline table into EXPERIMENTS.md from
experiments/roofline_final/*.json (falls back to experiments/roofline)."""
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def build_table(d: pathlib.Path) -> str:
    rows = []
    for f in sorted(d.glob("*__*.json")):
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            continue
        rows.append(r)
    out = ["| arch | shape | compute s | memory s | collective s |"
           " dominant | useful FLOPs | roofline frac | next lever |",
           "|---|---|---|---|---|---|---|---|---|"]
    LEVER = {
        ("collective", "train"): "shard_map all-to-all MoE dispatch / "
                                 "fewer FSDP regathers",
        ("memory", "train"): "fuse QKV+GU matmuls; bf16-native fusions "
                             "(CPU bytes are upper bounds)",
        ("memory", "prefill"): "fuse quantize into matmuls "
                               "(hadamard_quant/mx_matmul kernels)",
        ("memory", "decode"): "packed 4-bit weights via mx_matmul kernel "
                              "(3.76x less weight traffic) + MX KV cache",
        ("collective", "prefill"): "head-stationary attention layout",
        ("collective", "decode"): "replicate small params",
        ("compute", "train"): "less remat (save dot outputs)",
    }
    for r in rows:
        t = r["terms_s"]
        kind = ("train" if r["shape"].startswith("train") else
                "prefill" if r["shape"].startswith("prefill") else "decode")
        lever = LEVER.get((r["dominant"], kind), "—")
        out.append(
            f"| {r['arch']} | {r['shape']} | {t['compute']:.3f} | "
            f"{t['memory']:.3f} | {t['collective']:.3f} | {r['dominant']} | "
            f"{r['useful_flops_ratio']:.2f} | {r['roofline_fraction']:.3f} |"
            f" {lever} |")
    return "\n".join(out)


def main():
    src = ROOT / "experiments/roofline_final"
    if not any(src.glob("*__*.json")):
        src = ROOT / "experiments/roofline"
    table = build_table(src)
    exp = ROOT / "EXPERIMENTS.md"
    text = exp.read_text()
    marker = "<!-- ROOFLINE_TABLE -->"
    start = text.index(marker)
    end = text.index("## §Perf")
    text = text[:start] + marker + "\n\n" + table + "\n\n" + text[end:]
    exp.write_text(text)
    print(f"injected {table.count(chr(10))-1} rows from {src.name}")


if __name__ == "__main__":
    main()
