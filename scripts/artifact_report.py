"""Summarize exported MX artifacts: packed footprint vs the fp16/fp32
equivalent, per artifact directory (the deployment-side view of the
roofline's 3.76x weight-traffic reduction).

    PYTHONPATH=src python scripts/artifact_report.py artifacts/ [more dirs]

Each argument may be an artifact directory (contains manifest.json) or a
parent directory scanned one level deep.
"""
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402,F401  (registers bfloat16 et al. with np.dtype)
import numpy as np  # noqa: E402

from repro.artifacts.manifest import MANIFEST_FILE, ArtifactError, Manifest  # noqa: E402


def _find(paths):
    for p in map(pathlib.Path, paths):
        if (p / MANIFEST_FILE).exists():
            yield p
        elif p.is_dir():
            for c in sorted(p.iterdir()):
                if (c / MANIFEST_FILE).exists():
                    yield c


def main(argv):
    roots = list(_find(argv or ["artifacts"]))
    if not roots:
        print("no artifact directories found", file=sys.stderr)
        return 1
    print(f"{'artifact':40s} {'method':14s} {'fmt':7s} "
          f"{'packed MiB':>10s} {'fp MiB':>8s} {'ratio':>6s}")
    for root in roots:
        try:
            man = Manifest.load(root / MANIFEST_FILE)
        except ArtifactError as e:
            print(f"{str(root):40s} SKIP ({e})")
            continue
        packed = man.packed_total_nbytes
        # fp equivalent of the quantized tensors, at their logical dtype
        fp = sum(int(np.prod(t.shape)) * np.dtype(t.dtype).itemsize
                 for t in man.tensors if t.kind == "packed")
        print(f"{str(root):40s} {man.method:14s} {man.fmt:7s} "
              f"{packed/2**20:10.2f} {fp/2**20:8.2f} "
              f"{fp/max(packed,1):5.2f}x")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
